//! `perf_trend` — compare a fresh bench JSON against a recorded baseline
//! (ROADMAP "wire a CI perf-trend check against recorded baselines").
//!
//! Both files are walked recursively; numeric leaves are matched by a
//! stable path (array elements are keyed by their identity fields — `n`,
//! `dim`, `threads`, `net`, `nranks`, `contended` — so reordering rows or
//! adding new ones never misattributes a metric). Each shared metric is
//! classified by its key:
//!
//! * `*alloc*`, `fault_*`, `ckpt_*`, `ranks_revived` and `rollback_steps`
//!   counts — **exact**: allocation, fault/injection and
//!   checkpoint/recovery counters are machine-independent (they pin the
//!   zero-allocation, fault-idle and restart contracts), so any increase
//!   is a regression regardless of tolerance. CI runs `--allocs-only` as
//!   a blocking step covering all of them.
//! * `*_s` — lower is better (timings): regression when the relative
//!   delta exceeds `--tol`. Advisory on shared runners (machine noise).
//! * `*gbs` / `*speedup*` / `*gain*` / `*efficiency*` — higher is better,
//!   same tolerance.
//! * anything else — informational only.
//!
//! Prints a markdown delta table (CI appends it to `$GITHUB_STEP_SUMMARY`)
//! and exits 2 on an allocation regression, 1 on a tolerance regression,
//! 0 otherwise. `--out` writes the full comparison as JSON for the
//! artifact upload.
//!
//!     cargo run --release --bin perf_trend -- \
//!         --baseline bench/baselines/BENCH_halo.json --current BENCH_halo.json

use std::collections::BTreeMap;

use igg::util::cli::Command;
use igg::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Class {
    /// Allocation and fault counters: exact, machine-independent, blocking.
    Exact,
    /// Timings (`*_s`): lower is better, tolerance applies.
    LowerBetter,
    /// Bandwidths/ratios: higher is better, tolerance applies.
    HigherBetter,
    /// Everything else: reported, never a regression.
    Info,
}

fn classify(path: &str) -> Class {
    // the metric key is the last `.`-separated segment
    let key = path.rsplit('.').next().unwrap_or(path);
    if key.contains("alloc")
        || key.starts_with("fault_")
        || key.starts_with("ckpt_")
        || key == "ranks_revived"
        || key == "rollback_steps"
    {
        Class::Exact
    } else if key.ends_with("_s") {
        Class::LowerBetter
    } else if key.ends_with("gbs")
        || key.contains("speedup")
        || key.contains("gain")
        || key.contains("efficiency")
    {
        Class::HigherBetter
    } else {
        Class::Info
    }
}

/// Identity fields used to key array elements, in label priority order.
/// `app` distinguishes the tenancy bench's per-job rows (two co-tenant
/// jobs can share a rank count but never an app+ranks pair there);
/// `every` keys the checkpoint-overhead cadence sweep.
const ID_KEYS: [&str; 8] = ["app", "n", "dim", "threads", "net", "nranks", "contended", "every"];

fn element_label(v: &Json, index: usize) -> String {
    if let Some(obj) = v.as_obj() {
        let parts: Vec<String> = ID_KEYS
            .iter()
            .filter_map(|k| obj.get(*k).map(|val| format!("{k}={}", plain(val))))
            .collect();
        if !parts.is_empty() {
            return parts.join(",");
        }
    }
    index.to_string()
}

/// A scalar rendered without quotes for labels.
fn plain(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

fn flatten(v: &Json, prefix: &str, out: &mut BTreeMap<String, f64>) {
    match v {
        Json::Num(x) => {
            out.insert(prefix.to_string(), *x);
        }
        Json::Obj(obj) => {
            for (k, child) in obj {
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(child, &p, out);
            }
        }
        Json::Arr(arr) => {
            for (i, child) in arr.iter().enumerate() {
                flatten(child, &format!("{prefix}[{}]", element_label(child, i)), out);
            }
        }
        _ => {}
    }
}

fn load(path: &str) -> anyhow::Result<BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let json = Json::from_str(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    let mut out = BTreeMap::new();
    flatten(&json, "", &mut out);
    Ok(out)
}

struct Row {
    path: String,
    class: Class,
    baseline: f64,
    current: f64,
    /// Signed relative delta, positive = worse for the metric's direction
    /// (0 for Info/Exact).
    badness: f64,
    status: &'static str,
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(3);
        }
    }
}

fn run(argv: &[String]) -> anyhow::Result<i32> {
    let cmd = Command::new("perf_trend", "compare a bench JSON against a recorded baseline")
        .required("baseline", "baseline JSON (bench/baselines/...)")
        .required("current", "fresh bench JSON to check")
        .value("tol", Some("0.5"), "relative tolerance for timing/bandwidth metrics")
        .value("out", None, "write the comparison JSON here")
        .switch("allocs-only", "check only allocation-count metrics (blocking CI step)");
    let args = cmd.parse(argv)?;
    let tol = args.get_f64("tol")?.expect("tol has a default");
    anyhow::ensure!(tol >= 0.0, "--tol must be >= 0");
    let allocs_only = args.get_flag("allocs-only");
    let base_path = args.get("baseline").expect("required").to_string();
    let cur_path = args.get("current").expect("required").to_string();
    let baseline = load(&base_path)?;
    let current = load(&cur_path)?;

    let mut rows: Vec<Row> = Vec::new();
    let mut alloc_regressions = 0usize;
    let mut tol_regressions = 0usize;
    let mut missing_allocs = 0usize;

    for (path, &base) in &baseline {
        let class = classify(path);
        if allocs_only && class != Class::Exact {
            continue;
        }
        let Some(&cur) = current.get(path) else {
            if class == Class::Exact {
                // an allocation column vanishing would silently drop the
                // zero-allocation gate — treat as a blocking failure
                missing_allocs += 1;
                rows.push(Row {
                    path: path.clone(),
                    class,
                    baseline: base,
                    current: f64::NAN,
                    badness: f64::INFINITY,
                    status: "MISSING",
                });
            }
            continue;
        };
        let denom = base.abs().max(1e-12);
        let (badness, status) = match class {
            Class::Exact => {
                if cur > base {
                    alloc_regressions += 1;
                    (f64::INFINITY, "ALLOC REGRESSION")
                } else {
                    (0.0, "ok (exact)")
                }
            }
            Class::LowerBetter => {
                let rel = (cur - base) / denom;
                if rel > tol {
                    tol_regressions += 1;
                    (rel, "REGRESSION")
                } else if rel < -tol {
                    (rel, "improved")
                } else {
                    (rel, "ok")
                }
            }
            Class::HigherBetter => {
                let rel = (base - cur) / denom;
                if rel > tol {
                    tol_regressions += 1;
                    (rel, "REGRESSION")
                } else if rel < -tol {
                    (rel, "improved")
                } else {
                    (rel, "ok")
                }
            }
            Class::Info => (0.0, "info"),
        };
        rows.push(Row { path: path.clone(), class, baseline: base, current: cur, badness, status });
    }

    // worst offenders first, then by path for stable output
    rows.sort_by(|a, b| {
        b.badness
            .partial_cmp(&a.badness)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.path.cmp(&b.path))
    });

    println!(
        "### perf trend — {} vs baseline {} (tol {:.0}%{})\n",
        cur_path,
        base_path,
        tol * 100.0,
        if allocs_only { ", allocation columns only" } else { "" }
    );
    println!("| metric | baseline | current | Δ (worse +) | status |");
    println!("|:---|---:|---:|---:|:---|");
    for r in &rows {
        let delta = match r.class {
            Class::Exact => format!("{:+}", r.current - r.baseline),
            _ => format!("{:+.1}%", r.badness * 100.0),
        };
        println!(
            "| `{}` | {} | {} | {} | {} |",
            r.path,
            fmt_val(r.baseline),
            fmt_val(r.current),
            delta,
            r.status
        );
    }
    let compared = rows.len();
    println!(
        "\n{compared} metrics compared: {tol_regressions} beyond tolerance, \
         {alloc_regressions} allocation regressions, {missing_allocs} allocation \
         columns missing."
    );

    if let Some(out) = args.get("out") {
        let body = Json::obj(vec![
            ("baseline", Json::Str(base_path.clone())),
            ("current", Json::Str(cur_path.clone())),
            ("tol", Json::Num(tol)),
            ("allocs_only", Json::Bool(allocs_only)),
            ("tol_regressions", Json::Num(tol_regressions as f64)),
            ("alloc_regressions", Json::Num((alloc_regressions + missing_allocs) as f64)),
            (
                "metrics",
                Json::Arr(
                    rows.iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("path", Json::Str(r.path.clone())),
                                ("baseline", Json::Num(r.baseline)),
                                (
                                    "current",
                                    if r.current.is_finite() {
                                        Json::Num(r.current)
                                    } else {
                                        Json::Null
                                    },
                                ),
                                ("status", Json::Str(r.status.to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        if let Some(dir) = std::path::Path::new(out).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(out, body.to_string())?;
        eprintln!("wrote {out}");
    }

    Ok(if alloc_regressions + missing_allocs > 0 {
        2
    } else if tol_regressions > 0 {
        1
    } else {
        0
    })
}

fn fmt_val(x: f64) -> String {
    if !x.is_finite() {
        "—".to_string()
    } else if x == 0.0 || (x.abs() >= 0.01 && x.abs() < 1e5) {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}
