"""L2: the step programs the Rust coordinator executes, built on the L1 kernels.

Besides the full-domain steps this module provides *region* variants used by
the `hide_communication` scheduler (paper Fig. 1 line 36): the interior of
the local domain is split into one inner region plus up to six boundary
slabs; the boundary slabs are computed first, their planes are sent while the
inner region computes. Each region program takes the FULL local arrays, has
XLA slice out the region plus its one-cell stencil ring (free — it fuses into
the kernel), and returns the dense updated region which Rust scatters into
the destination array.

Region convention: ``region = (ox, oy, oz, sx, sy, sz)`` in *local array*
coordinates; the region must lie strictly inside the array (ox >= 1,
ox + sx <= nx - 1, ...), matching ParallelStencil's computation ranges.
"""

import jax.numpy as jnp
from jax import lax

from .kernels import diffusion3d, twophase
from .kernels import x64  # noqa: F401

#: scalar-parameter order of the diffusion programs (after T, Ci).
DIFFUSION_SCALARS = ("lam", "dt", "dx", "dy", "dz")
#: scalar-parameter order of the two-phase programs (after Pe, phi).
TWOPHASE_SCALARS = twophase.SCALARS


def check_region(region, shape):
    ox, oy, oz, sx, sy, sz = region
    nx, ny, nz = shape
    for o, s, n, name in ((ox, sx, nx, "x"), (oy, sy, ny, "y"), (oz, sz, nz, "z")):
        if o < 1 or s < 1 or o + s > n - 1:
            raise ValueError(
                f"region {region} not strictly interior to {shape} in {name}"
            )


def _region_slice(a, region):
    """The region expanded by the one-cell stencil ring."""
    ox, oy, oz, sx, sy, sz = region
    return lax.slice(a, (ox - 1, oy - 1, oz - 1), (ox + sx + 1, oy + sy + 1, oz + sz + 1))


def diffusion_step(T, Ci, lam, dt, dx, dy, dz):
    """Full-domain heat diffusion step (paper Fig. 1 `step!`): returns T2."""
    return diffusion3d.step(T, Ci, lam, dt, dx, dy, dz)


def diffusion_region(region):
    """Step program for one region; returns fn(T, Ci, scalars...) -> U."""

    def fn(T, Ci, lam, dt, dx, dy, dz):
        check_region(region, T.shape)
        Ts = _region_slice(T, region)
        Cis = _region_slice(Ci, region)
        out = diffusion3d.step(Ts, Cis, lam, dt, dx, dy, dz)
        return out[1:-1, 1:-1, 1:-1]

    return fn


def twophase_step(Pe, phi, *scalars):
    """Full-domain two-phase iteration: returns (Pe2, phi2)."""
    return twophase.step(Pe, phi, *scalars)


def twophase_region(region):
    """Region variant of the two-phase iteration: returns (UPe, Uphi)."""

    def fn(Pe, phi, *scalars):
        check_region(region, Pe.shape)
        Pes = _region_slice(Pe, region)
        phis = _region_slice(phi, region)
        Pe2, phi2 = twophase.step(Pes, phis, *scalars)
        return Pe2[1:-1, 1:-1, 1:-1], phi2[1:-1, 1:-1, 1:-1]

    return fn


def split_regions(shape, widths):
    """Decompose the interior of ``shape`` for ``hide_communication(widths)``.

    Returns ``(inner, boundaries)`` where ``boundaries`` is a list of
    ``(name, region)`` covering the interior cells within ``widths`` of the
    domain edge, disjointly, in the order xlo, xhi, ylo, yhi, zlo, zhi.
    Mirrors ParallelStencil's `@hide_communication` ranges; the Rust
    `overlap::regions` module implements the identical decomposition (tested
    against each other through the AOT artifacts).
    """
    nx, ny, nz = shape
    wx, wy, wz = widths
    # Interior computation range is [1, n-1); clamp widths into it.
    if min(nx, ny, nz) < 3:
        raise ValueError(f"shape {shape} has no interior")
    if 2 * wx > nx - 2 or 2 * wy > ny - 2 or 2 * wz > nz - 2:
        raise ValueError(f"widths {widths} leave no inner region in {shape}")
    ix0, ix1 = (max(wx, 1), nx - max(wx, 1))
    iy0, iy1 = (max(wy, 1), ny - max(wy, 1))
    iz0, iz1 = (max(wz, 1), nz - max(wz, 1))
    inner = (ix0, iy0, iz0, ix1 - ix0, iy1 - iy0, iz1 - iz0)
    boundaries = []
    if ix0 > 1:
        boundaries.append(("xlo", (1, 1, 1, ix0 - 1, ny - 2, nz - 2)))
    if ix1 < nx - 1:
        boundaries.append(("xhi", (ix1, 1, 1, nx - 1 - ix1, ny - 2, nz - 2)))
    if iy0 > 1:
        boundaries.append(("ylo", (ix0, 1, 1, ix1 - ix0, iy0 - 1, nz - 2)))
    if iy1 < ny - 1:
        boundaries.append(("yhi", (ix0, iy1, 1, ix1 - ix0, ny - 1 - iy1, nz - 2)))
    if iz0 > 1:
        boundaries.append(("zlo", (ix0, iy0, 1, ix1 - ix0, iy1 - iy0, iz0 - 1)))
    if iz1 < nz - 1:
        boundaries.append(("zhi", (ix0, iy0, iz1, ix1 - ix0, iy1 - iy0, nz - 1 - iz1)))
    return inner, boundaries


def scatter_region(dst, U, region):
    """Write region update U into dst (reference composition used in tests)."""
    ox, oy, oz = region[:3]
    return lax.dynamic_update_slice(dst, U, (ox, oy, oz))
