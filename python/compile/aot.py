"""AOT compile path: lower the L2 step programs to HLO *text* artifacts.

Run once by ``make artifacts``; Python is never on the Rust request path.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). Programs are lowered
with ``return_tuple=True`` so every artifact's result is a tuple, which the
Rust runtime unpacks uniformly.

Each artifact is one (program, local-array-shape[, region-set]) pair — array
shapes are static in HLO, so the Rust runtime picks the artifact matching the
local grid and caches the compiled executable. ``manifest.json`` is the
machine-readable index the Rust `runtime::artifacts` module loads.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import x64  # noqa: F401

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _lower(fn, arg_shapes):
    args = [jax.ShapeDtypeStruct(s, F64) for s in arg_shapes]
    return to_hlo_text(jax.jit(fn).lower(*args))


def _scalar_shapes(names):
    return [()] * len(names)


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.entries = []

    def emit(self, name, fn, arrays_in, scalars, arrays_out, meta):
        """Lower ``fn`` and record a manifest entry.

        arrays_in / arrays_out: list of (param_name, shape) tuples.
        scalars: tuple of scalar param names (appended after arrays_in).
        """
        shapes = [s for (_, s) in arrays_in] + _scalar_shapes(scalars)
        text = _lower(fn, shapes)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "arrays_in": [{"name": n, "shape": list(s)} for (n, s) in arrays_in],
            "scalars": list(scalars),
            "arrays_out": [{"name": n, "shape": list(s)} for (n, s) in arrays_out],
        }
        entry.update(meta)
        self.entries.append(entry)
        print(f"  {fname}  ({len(text)} chars)")

    def emit_diffusion_full(self, shape):
        nx, ny, nz = shape
        self.emit(
            f"diffusion_step__{nx}x{ny}x{nz}",
            model.diffusion_step,
            [("T", shape), ("Ci", shape)],
            model.DIFFUSION_SCALARS,
            [("T2", shape)],
            {"app": "diffusion", "kind": "full", "shape": list(shape)},
        )

    def emit_twophase_full(self, shape):
        nx, ny, nz = shape
        self.emit(
            f"twophase_step__{nx}x{ny}x{nz}",
            model.twophase_step,
            [("Pe", shape), ("phi", shape)],
            model.TWOPHASE_SCALARS,
            [("Pe2", shape), ("phi2", shape)],
            {"app": "twophase", "kind": "full", "shape": list(shape)},
        )

    def emit_region_set(self, app, shape, widths):
        nx, ny, nz = shape
        wx, wy, wz = widths
        inner, boundaries = model.split_regions(shape, widths)
        regions = [("inner", inner)] + boundaries
        for rname, region in regions:
            sx, sy, sz = region[3:]
            if app == "diffusion":
                fn = model.diffusion_region(region)
                arrays_in = [("T", shape), ("Ci", shape)]
                scalars = model.DIFFUSION_SCALARS
                arrays_out = [("U", (sx, sy, sz))]
            else:
                fn = model.twophase_region(region)
                arrays_in = [("Pe", shape), ("phi", shape)]
                scalars = model.TWOPHASE_SCALARS
                arrays_out = [("UPe", (sx, sy, sz)), ("Uphi", (sx, sy, sz))]
            self.emit(
                f"{app}_{rname}__{nx}x{ny}x{nz}__w{wx}x{wy}x{wz}",
                fn,
                arrays_in,
                scalars,
                arrays_out,
                {
                    "app": app,
                    "kind": f"region:{rname}",
                    "shape": list(shape),
                    "widths": list(widths),
                    "region": list(region),
                },
            )

    def write_manifest(self):
        manifest = {
            "format": 1,
            "overlap": 2,
            "dtype": "f64",
            "layout": "C (z fastest), shape (nx, ny, nz)",
            "diffusion_scalars": list(model.DIFFUSION_SCALARS),
            "twophase_scalars": list(model.TWOPHASE_SCALARS),
            "programs": self.entries,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"wrote {path} ({len(self.entries)} programs)")


# The default artifact set: small shapes for tests/examples, 64^3 for the
# single-node benches, one non-cubic shape to catch axis-order bugs, and the
# region sets used by hide_communication.
DEFAULT_FULL_DIFFUSION = [(8, 8, 8), (16, 16, 16), (32, 32, 32), (64, 64, 64), (24, 16, 12)]
DEFAULT_FULL_TWOPHASE = [(8, 8, 8), (16, 16, 16), (32, 32, 32), (64, 64, 64)]
DEFAULT_REGION_SETS = [
    ("diffusion", (16, 16, 16), (4, 2, 2)),
    ("diffusion", (32, 32, 32), (4, 2, 2)),
    ("diffusion", (64, 64, 64), (16, 2, 2)),
    ("twophase", (32, 32, 32), (4, 2, 2)),
]


def build(out_dir, tiny=False):
    os.makedirs(out_dir, exist_ok=True)
    b = Builder(out_dir)
    if tiny:  # fast set for python unit tests of the AOT path itself
        b.emit_diffusion_full((8, 8, 8))
        b.emit_region_set("diffusion", (8, 8, 8), (2, 2, 2))
        b.emit_twophase_full((8, 8, 8))
    else:
        for shape in DEFAULT_FULL_DIFFUSION:
            b.emit_diffusion_full(shape)
        for shape in DEFAULT_FULL_TWOPHASE:
            b.emit_twophase_full(shape)
        for app, shape, widths in DEFAULT_REGION_SETS:
            b.emit_region_set(app, shape, widths)
    b.write_manifest()


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact directory")
    p.add_argument("--tiny", action="store_true", help="emit the tiny test set")
    args = p.parse_args()
    build(args.out, tiny=args.tiny)


if __name__ == "__main__":
    main()
