"""L1 Pallas kernel for the two-phase flow pseudo-transient iteration.

This is the Fig. 3 solver of the paper, reduced to the porosity-wave
hydro-mechanical core (see DESIGN.md §2 for why the reduction preserves the
communication pattern): two halo-exchanged cell-centered fields (Pe, phi) and
three face-staggered Darcy-flux arrays that stay kernel-local — the classic
staggered-grid layout ImplicitGlobalGrid is designed around.

Validated against ref.twophase_step; lowered AOT with interpret=True.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import x64  # noqa: F401

# Runtime scalar parameters, in HLO parameter order after the field params.
SCALARS = ("dtau", "dt", "dx", "dy", "dz", "eta", "rhog", "phiref", "npow")


def _step_kernel(pe_ref, phi_ref, *rest):
    (
        dtau_ref,
        dt_ref,
        dx_ref,
        dy_ref,
        dz_ref,
        eta_ref,
        rhog_ref,
        phiref_ref,
        npow_ref,
        pe2_ref,
        phi2_ref,
    ) = rest
    Pe = pe_ref[...]
    phi = phi_ref[...]
    dtau = dtau_ref[0]
    dt = dt_ref[0]
    dx = dx_ref[0]
    dy = dy_ref[0]
    dz = dz_ref[0]
    eta = eta_ref[0]
    rhog = rhog_ref[0]
    phiref = phiref_ref[0]
    npow = npow_ref[0]

    # Mobility at cell centers, then averaged onto faces (staggered grid).
    k = (phi / phiref) ** npow

    kx = 0.5 * (k[:-1, 1:-1, 1:-1] + k[1:, 1:-1, 1:-1])
    qx = -kx * (Pe[1:, 1:-1, 1:-1] - Pe[:-1, 1:-1, 1:-1]) / dx

    ky = 0.5 * (k[1:-1, :-1, 1:-1] + k[1:-1, 1:, 1:-1])
    qy = -ky * (Pe[1:-1, 1:, 1:-1] - Pe[1:-1, :-1, 1:-1]) / dy

    kz = 0.5 * (k[1:-1, 1:-1, :-1] + k[1:-1, 1:-1, 1:])
    qz = -kz * ((Pe[1:-1, 1:-1, 1:] - Pe[1:-1, 1:-1, :-1]) / dz - rhog)

    divq = (
        (qx[1:, :, :] - qx[:-1, :, :]) / dx
        + (qy[:, 1:, :] - qy[:, :-1, :]) / dy
        + (qz[:, :, 1:] - qz[:, :, :-1]) / dz
    )

    Pe_inn = Pe[1:-1, 1:-1, 1:-1]
    phi_inn = phi[1:-1, 1:-1, 1:-1]
    RPe = -divq - Pe_inn / (eta * (1.0 - phi_inn))
    Pe2_inn = Pe_inn + dtau * RPe
    phi2_inn = phi_inn + dt * (1.0 - phi_inn) * Pe2_inn / eta

    pad = ((1, 1), (1, 1), (1, 1))
    pe2_ref[...] = Pe + jnp.pad(Pe2_inn - Pe_inn, pad)
    phi2_ref[...] = phi + jnp.pad(phi2_inn - phi_inn, pad)


def step(Pe, phi, dtau, dt, dx, dy, dz, eta, rhog, phiref, npow):
    """One pseudo-transient iteration; returns (Pe2, phi2)."""
    scalars = [
        jnp.reshape(jnp.float64(s), (1,))
        for s in (dtau, dt, dx, dy, dz, eta, rhog, phiref, npow)
    ]
    return pl.pallas_call(
        _step_kernel,
        out_shape=[
            jax.ShapeDtypeStruct(Pe.shape, Pe.dtype),
            jax.ShapeDtypeStruct(phi.shape, phi.dtype),
        ],
        interpret=True,
    )(Pe, phi, *scalars)
