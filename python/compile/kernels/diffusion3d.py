"""L1 Pallas kernels for the 3-D heat diffusion stencil (paper Fig. 1).

Two variants, both validated against ref.diffusion_step:

* ``step`` — single-block kernel: the whole local array is one Pallas block
  and the 7-point Laplacian is expressed as shifted-slice vector arithmetic.
  This is the variant AOT-lowered into the production artifacts: on CPU-PJRT
  (interpret=True) blocking buys nothing, and the shifted-slice form is what
  XLA fuses best.

* ``step_tiled`` — the TPU-shaped variant from DESIGN.md §8: the grid streams
  (nx, ny, bz) z-slabs HBM->VMEM with a one-plane halo-in-VMEM on each side of
  the slab (the in-kernel analog of the distributed halo). On a real TPU this
  is the memory schedule that keeps the VMEM working set bounded; here it
  runs under interpret=True for numerics validation only.

Scalars (lam, dt, dx, dy, dz) enter as shape-(1,) f64 refs so they stay
run-time HLO parameters: one artifact per array shape serves any physics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import x64  # noqa: F401


def _step_kernel(t_ref, ci_ref, lam_ref, dt_ref, dx_ref, dy_ref, dz_ref, o_ref):
    T = t_ref[...]
    Ci = ci_ref[...]
    lam = lam_ref[0]
    dt = dt_ref[0]
    dx = dx_ref[0]
    dy = dy_ref[0]
    dz = dz_ref[0]
    lap = (
        (T[2:, 1:-1, 1:-1] - 2.0 * T[1:-1, 1:-1, 1:-1] + T[:-2, 1:-1, 1:-1]) / dx**2
        + (T[1:-1, 2:, 1:-1] - 2.0 * T[1:-1, 1:-1, 1:-1] + T[1:-1, :-2, 1:-1]) / dy**2
        + (T[1:-1, 1:-1, 2:] - 2.0 * T[1:-1, 1:-1, 1:-1] + T[1:-1, 1:-1, :-2]) / dz**2
    )
    delta = dt * lam * Ci[1:-1, 1:-1, 1:-1] * lap
    o_ref[...] = T + jnp.pad(delta, ((1, 1), (1, 1), (1, 1)))


def step(T, Ci, lam, dt, dx, dy, dz):
    """Full-domain diffusion step: returns T2 with updated interior."""
    scalars = [jnp.reshape(jnp.float64(s), (1,)) for s in (lam, dt, dx, dy, dz)]
    return pl.pallas_call(
        _step_kernel,
        out_shape=jax.ShapeDtypeStruct(T.shape, T.dtype),
        interpret=True,
    )(T, Ci, *scalars)


def _step_tiled_kernel(
    bz, t_ref, ci_ref, lam_ref, dt_ref, dx_ref, dy_ref, dz_ref, o_ref
):
    # One program per interior z-slab. The input refs hold the full arrays
    # (on TPU: resident in HBM/ANY); each program loads a (nx, ny, bz+2) slab
    # — the +2 is the halo-in-VMEM — computes the update for its bz interior
    # planes, and stores a full (nx, ny, bz) output block whose x/y boundary
    # rows carry the input values through unchanged.
    # program_id is int32; promote so the dynamic-slice starts agree in type.
    i = pl.program_id(0).astype(jnp.int64)
    z0 = i * bz  # global z index of the first output plane is z0 + 1
    lam = lam_ref[0]
    dt = dt_ref[0]
    dx = dx_ref[0]
    dy = dy_ref[0]
    dz = dz_ref[0]

    nx, ny, _ = t_ref.shape
    slab = pl.load(t_ref, (slice(None), slice(None), pl.dslice(z0, bz + 2)))
    ci = pl.load(ci_ref, (slice(1, nx - 1), slice(1, ny - 1), pl.dslice(z0 + 1, bz)))

    lap = (
        (slab[2:, 1:-1, 1:-1] - 2.0 * slab[1:-1, 1:-1, 1:-1] + slab[:-2, 1:-1, 1:-1])
        / dx**2
        + (slab[1:-1, 2:, 1:-1] - 2.0 * slab[1:-1, 1:-1, 1:-1] + slab[1:-1, :-2, 1:-1])
        / dy**2
        + (slab[1:-1, 1:-1, 2:] - 2.0 * slab[1:-1, 1:-1, 1:-1] + slab[1:-1, 1:-1, :-2])
        / dz**2
    )
    out = slab[:, :, 1:-1]
    out = out.at[1:-1, 1:-1, :].add(dt * lam * ci * lap)
    pl.store(o_ref, (slice(None), slice(None), pl.dslice(z0, bz)), out)


def step_tiled(T, Ci, lam, dt, dx, dy, dz, bz=None):
    """Diffusion step streamed over interior z-slabs of thickness ``bz``.

    Requires ``(nz - 2) % bz == 0``; defaults to the largest divisor <= 8.
    """
    nx, ny, nz = T.shape
    nzi = nz - 2
    if bz is None:
        bz = next(b for b in range(min(8, nzi), 0, -1) if nzi % b == 0)
    if nzi % bz != 0:
        raise ValueError(f"bz={bz} must divide nz-2={nzi}")
    scalars = [jnp.reshape(jnp.float64(s), (1,)) for s in (lam, dt, dx, dy, dz)]
    inner = pl.pallas_call(
        functools.partial(_step_tiled_kernel, bz),
        grid=(nzi // bz,),
        out_shape=jax.ShapeDtypeStruct((nx, ny, nzi), T.dtype),
        interpret=True,
    )(T, Ci, *scalars)
    return jnp.concatenate([T[:, :, :1], inner, T[:, :, -1:]], axis=2)
