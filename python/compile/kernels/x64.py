"""Enable f64 once, on first import, for every module in the compile path.

The paper's solvers run in Float64; JAX defaults to f32 unless x64 is enabled
before any array is created.
"""

import jax

jax.config.update("jax_enable_x64", True)
