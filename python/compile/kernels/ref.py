"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are straight transcriptions of the paper's stencils (Fig. 1 for the
heat diffusion; the porosity-wave two-phase flow model for the Fig. 3
solver — see DESIGN.md §2 for the substitution note) with no Pallas in the
loop. The L1 kernels must match these to f64 round-off; the Rust-native
implementations in rust/src/physics/ are a third, independent transcription
tested against the AOT artifacts in cargo tests.

Array convention: shape (nx, ny, nz), C order (z fastest) — identical to the
Rust Field3D layout, so HLO parameters round-trip without relayout.
"""

import jax.numpy as jnp

from . import x64  # noqa: F401  (enables f64 on import)


def diffusion_step(T, Ci, lam, dt, dx, dy, dz):
    """One explicit step of 3-D heat diffusion (paper Fig. 1 `step!`).

    T2 = T with the interior updated:
        T2_inn = T_inn + dt * lam * Ci_inn * (d2_xi(T)/dx^2 +
                                              d2_yi(T)/dy^2 + d2_zi(T)/dz^2)
    Boundary planes are carried over from T unchanged: physical boundaries
    keep their (Dirichlet) initial values, halo planes are overwritten by
    `update_halo!` right after the step.
    """
    lap = (
        (T[2:, 1:-1, 1:-1] - 2.0 * T[1:-1, 1:-1, 1:-1] + T[:-2, 1:-1, 1:-1]) / dx**2
        + (T[1:-1, 2:, 1:-1] - 2.0 * T[1:-1, 1:-1, 1:-1] + T[1:-1, :-2, 1:-1]) / dy**2
        + (T[1:-1, 1:-1, 2:] - 2.0 * T[1:-1, 1:-1, 1:-1] + T[1:-1, 1:-1, :-2]) / dz**2
    )
    delta = dt * lam * Ci[1:-1, 1:-1, 1:-1] * lap
    return T + jnp.pad(delta, ((1, 1), (1, 1), (1, 1)))


def twophase_step(Pe, phi, dtau, dt, dx, dy, dz, eta, rhog, phiref, npow):
    """One pseudo-transient iteration of the porosity-wave two-phase solver.

    Cell-centered fields Pe (effective pressure) and phi (porosity);
    face-staggered Darcy fluxes (the size-(n-1) arrays of the staggered
    grid — they are kernel-local and never halo-exchanged, exactly like in
    the paper's solver):

        k    = (phi / phiref)^npow                        (centers)
        q_d  = -k_face * (dPe/dd - rhog * [d==z])         (faces, interior)
        RPe  = -div(q) - Pe / (eta * (1 - phi))           (interior centers)
        Pe'  = Pe + dtau * RPe
        phi' = phi + dt * (1 - phi) * Pe' / eta

    Returns (Pe', phi') with boundary planes carried over unchanged.
    """
    k = (phi / phiref) ** npow

    kx = 0.5 * (k[:-1, 1:-1, 1:-1] + k[1:, 1:-1, 1:-1])
    qx = -kx * (Pe[1:, 1:-1, 1:-1] - Pe[:-1, 1:-1, 1:-1]) / dx

    ky = 0.5 * (k[1:-1, :-1, 1:-1] + k[1:-1, 1:, 1:-1])
    qy = -ky * (Pe[1:-1, 1:, 1:-1] - Pe[1:-1, :-1, 1:-1]) / dy

    kz = 0.5 * (k[1:-1, 1:-1, :-1] + k[1:-1, 1:-1, 1:])
    qz = -kz * ((Pe[1:-1, 1:-1, 1:] - Pe[1:-1, 1:-1, :-1]) / dz - rhog)

    divq = (
        (qx[1:, :, :] - qx[:-1, :, :]) / dx
        + (qy[:, 1:, :] - qy[:, :-1, :]) / dy
        + (qz[:, :, 1:] - qz[:, :, :-1]) / dz
    )

    Pe_inn = Pe[1:-1, 1:-1, 1:-1]
    phi_inn = phi[1:-1, 1:-1, 1:-1]
    RPe = -divq - Pe_inn / (eta * (1.0 - phi_inn))
    Pe2_inn = Pe_inn + dtau * RPe
    phi2_inn = phi_inn + dt * (1.0 - phi_inn) * Pe2_inn / eta

    pad = ((1, 1), (1, 1), (1, 1))
    Pe2 = Pe + jnp.pad(Pe2_inn - Pe_inn, pad)
    phi2 = phi + jnp.pad(phi2_inn - phi_inn, pad)
    return Pe2, phi2
