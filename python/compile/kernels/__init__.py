# L1: Pallas kernels for the paper's stencil hot loops.
#
# Every kernel has a pure-jnp oracle in ref.py; pytest + hypothesis assert
# allclose between the two over random shapes and values. Kernels are always
# instantiated with interpret=True: the CPU PJRT plugin cannot execute Mosaic
# custom-calls, and interpret-mode lowers to plain HLO that the Rust runtime
# (xla crate) compiles and runs (see /opt/xla-example/README.md).

from . import diffusion3d, ref, twophase

__all__ = ["diffusion3d", "twophase", "ref"]
