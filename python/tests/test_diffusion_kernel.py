"""L1 correctness: Pallas diffusion kernels vs the pure-jnp oracle.

This is the core correctness signal for the compute hot-spot: both kernel
variants (single-block and z-slab tiled) must match ref.diffusion_step to
f64 round-off over random shapes, dtypes kept at f64 (the paper's precision),
and random field values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import diffusion3d, ref

jax.config.update("jax_enable_x64", True)


def rand_fields(rng, shape):
    T = jnp.asarray(rng.standard_normal(shape))
    Ci = jnp.asarray(rng.uniform(0.1, 1.0, shape))
    return T, Ci


PARAMS = dict(lam=1.7, dt=1e-4, dx=0.11, dy=0.13, dz=0.17)


def test_step_matches_ref_fixed_shape():
    rng = np.random.default_rng(0)
    T, Ci = rand_fields(rng, (12, 10, 14))
    got = diffusion3d.step(T, Ci, **PARAMS)
    want = ref.diffusion_step(T, Ci, **PARAMS)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=0)


def test_step_preserves_boundary_planes():
    rng = np.random.default_rng(1)
    T, Ci = rand_fields(rng, (8, 9, 10))
    T2 = diffusion3d.step(T, Ci, **PARAMS)
    for axis in range(3):
        for idx in (0, -1):
            np.testing.assert_array_equal(
                np.take(np.asarray(T2), idx, axis=axis),
                np.take(np.asarray(T), idx, axis=axis),
            )


def test_step_max_principle():
    # With a stable dt, explicit diffusion cannot create new extrema.
    rng = np.random.default_rng(2)
    shape = (16, 16, 16)
    T = jnp.asarray(rng.uniform(0.0, 1.0, shape))
    Ci = jnp.ones(shape) / 2.0
    dx = dy = dz = 1.0 / 15
    lam = 1.0
    dt = min(dx, dy, dz) ** 2 / lam / jnp.max(Ci).item() / 6.1
    T2 = diffusion3d.step(T, Ci, lam, dt, dx, dy, dz)
    assert float(jnp.max(T2)) <= float(jnp.max(T)) + 1e-12
    assert float(jnp.min(T2)) >= float(jnp.min(T)) - 1e-12


def test_zero_laplacian_is_fixed_point():
    # A globally linear field has zero Laplacian: step must be the identity.
    nx, ny, nz = 9, 8, 7
    x, y, z = jnp.meshgrid(
        jnp.arange(nx, dtype=jnp.float64),
        jnp.arange(ny, dtype=jnp.float64),
        jnp.arange(nz, dtype=jnp.float64),
        indexing="ij",
    )
    T = 0.3 * x + 0.5 * y - 0.2 * z + 1.0
    Ci = jnp.ones((nx, ny, nz))
    T2 = diffusion3d.step(T, Ci, **PARAMS)
    np.testing.assert_allclose(T2, T, rtol=0, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    nx=st.integers(3, 14),
    ny=st.integers(3, 14),
    nz=st.integers(3, 14),
    seed=st.integers(0, 2**31 - 1),
    lam=st.floats(0.1, 10.0),
    dt=st.floats(1e-6, 1e-3),
)
def test_step_matches_ref_hypothesis(nx, ny, nz, seed, lam, dt):
    rng = np.random.default_rng(seed)
    T, Ci = rand_fields(rng, (nx, ny, nz))
    got = diffusion3d.step(T, Ci, lam, dt, 0.1, 0.2, 0.3)
    want = ref.diffusion_step(T, Ci, lam, dt, 0.1, 0.2, 0.3)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-15)


@settings(max_examples=15, deadline=None)
@given(
    nx=st.integers(3, 12),
    ny=st.integers(3, 12),
    nzi=st.integers(1, 10),
    bz_choice=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_step_tiled_matches_ref_hypothesis(nx, ny, nzi, bz_choice, seed):
    divisors = [b for b in range(1, nzi + 1) if nzi % b == 0]
    bz = divisors[bz_choice % len(divisors)]
    nz = nzi + 2
    rng = np.random.default_rng(seed)
    T, Ci = rand_fields(rng, (nx, ny, nz))
    got = diffusion3d.step_tiled(T, Ci, bz=bz, **PARAMS)
    want = ref.diffusion_step(T, Ci, **PARAMS)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-15)


def test_step_tiled_rejects_bad_bz():
    T = jnp.zeros((6, 6, 7))  # nz-2 = 5
    with pytest.raises(ValueError):
        diffusion3d.step_tiled(T, T, bz=2, **PARAMS)


def test_step_tiled_default_bz():
    rng = np.random.default_rng(3)
    T, Ci = rand_fields(rng, (7, 7, 18))  # nz-2 = 16 -> bz = 8
    got = diffusion3d.step_tiled(T, Ci, **PARAMS)
    want = ref.diffusion_step(T, Ci, **PARAMS)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-15)
