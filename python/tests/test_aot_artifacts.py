"""AOT path: artifacts exist, are valid HLO text, and the manifest indexes them."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out), tiny=True)
    return str(out)


def _manifest(d):
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)


def test_manifest_written(tiny_artifacts):
    m = _manifest(tiny_artifacts)
    assert m["format"] == 1
    assert m["overlap"] == 2
    assert m["dtype"] == "f64"
    assert m["diffusion_scalars"] == list(model.DIFFUSION_SCALARS)
    assert m["twophase_scalars"] == list(model.TWOPHASE_SCALARS)
    assert len(m["programs"]) >= 3


def test_all_program_files_exist_and_are_hlo_text(tiny_artifacts):
    m = _manifest(tiny_artifacts)
    for prog in m["programs"]:
        path = os.path.join(tiny_artifacts, prog["file"])
        assert os.path.exists(path), prog["file"]
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # return_tuple=True: the root computation returns a tuple
        assert "tuple(" in text or "ROOT" in text


def test_full_program_shapes(tiny_artifacts):
    m = _manifest(tiny_artifacts)
    full = [p for p in m["programs"] if p["kind"] == "full" and p["app"] == "diffusion"]
    assert full
    p = full[0]
    assert [a["name"] for a in p["arrays_in"]] == ["T", "Ci"]
    assert p["scalars"] == list(model.DIFFUSION_SCALARS)
    assert p["arrays_out"][0]["shape"] == p["shape"]
    text = open(os.path.join(tiny_artifacts, p["file"])).read()
    # All array params and the 5 scalars appear as f64 parameters.
    assert text.count("f64[8,8,8]") >= 3
    assert text.count("f64[]") >= len(model.DIFFUSION_SCALARS)


def test_region_programs_cover_interior(tiny_artifacts):
    m = _manifest(tiny_artifacts)
    regions = [p for p in m["programs"] if p["kind"].startswith("region:")]
    assert regions
    shape = regions[0]["shape"]
    seen = set()
    total = 0
    for p in regions:
        ox, oy, oz, sx, sy, sz = p["region"]
        assert p["arrays_out"][0]["shape"] == [sx, sy, sz]
        for i in range(ox, ox + sx):
            for j in range(oy, oy + sy):
                for k in range(oz, oz + sz):
                    assert (i, j, k) not in seen
                    seen.add((i, j, k))
        total += sx * sy * sz
    nx, ny, nz = shape
    assert total == (nx - 2) * (ny - 2) * (nz - 2)


def test_twophase_program_has_two_outputs(tiny_artifacts):
    m = _manifest(tiny_artifacts)
    tp = [p for p in m["programs"] if p["app"] == "twophase" and p["kind"] == "full"]
    assert tp and len(tp[0]["arrays_out"]) == 2
