"""L2 correctness: region decomposition composes back to the full step.

`hide_communication` correctness rests on this: computing the inner region
plus the six boundary slabs and scattering them into T2 must equal the
full-domain step exactly (bitwise in f64 — the same kernel runs on each
region with identical operand values).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

PARAMS = dict(lam=1.7, dt=1e-4, dx=0.11, dy=0.13, dz=0.17)
TP_PARAMS = dict(
    dtau=1e-4, dt=1e-3, dx=0.1, dy=0.12, dz=0.09, eta=1.0, rhog=1.0, phiref=0.05, npow=3.0
)


def compose_diffusion(T, Ci, widths):
    inner, boundaries = model.split_regions(T.shape, widths)
    T2 = jnp.array(T)  # boundaries carried over, like the Rust runtime does
    for _, region in [("inner", inner)] + boundaries:
        U = model.diffusion_region(region)(T, Ci, **PARAMS)
        T2 = model.scatter_region(T2, U, region)
    return T2


def test_split_regions_disjoint_cover():
    shape = (16, 12, 14)
    widths = (4, 2, 3)
    inner, boundaries = model.split_regions(shape, widths)
    count = np.zeros(shape, dtype=int)
    for _, (ox, oy, oz, sx, sy, sz) in [("inner", inner)] + boundaries:
        count[ox : ox + sx, oy : oy + sy, oz : oz + sz] += 1
    # interior covered exactly once, boundary planes never
    assert (count[1:-1, 1:-1, 1:-1] == 1).all()
    count[1:-1, 1:-1, 1:-1] = 0
    assert (count == 0).all()


def test_split_regions_boundary_names_and_order():
    inner, boundaries = model.split_regions((16, 16, 16), (4, 2, 2))
    assert [n for n, _ in boundaries] == ["xlo", "xhi", "ylo", "yhi", "zlo", "zhi"]
    assert inner == (4, 2, 2, 8, 12, 12)


def test_split_regions_zero_width_skips_axis():
    inner, boundaries = model.split_regions((10, 10, 10), (0, 2, 2))
    names = [n for n, _ in boundaries]
    assert "xlo" not in names and "xhi" not in names
    assert inner[0] == 1 and inner[3] == 8


def test_split_regions_rejects_too_wide():
    with pytest.raises(ValueError):
        model.split_regions((8, 8, 8), (4, 2, 2))  # 2*4 > 8-2


def test_split_regions_rejects_no_interior():
    with pytest.raises(ValueError):
        model.split_regions((2, 8, 8), (0, 0, 0))


def test_region_rejects_non_interior():
    T = jnp.zeros((8, 8, 8))
    with pytest.raises(ValueError):
        model.diffusion_region((0, 1, 1, 3, 3, 3))(T, T, **PARAMS)
    with pytest.raises(ValueError):
        model.diffusion_region((1, 1, 1, 7, 3, 3))(T, T, **PARAMS)


def test_diffusion_regions_compose_to_full_step():
    rng = np.random.default_rng(0)
    shape = (16, 12, 14)
    T = jnp.asarray(rng.standard_normal(shape))
    Ci = jnp.asarray(rng.uniform(0.1, 1.0, shape))
    got = compose_diffusion(T, Ci, (4, 2, 3))
    want = ref.diffusion_step(T, Ci, **PARAMS)
    # XLA may fuse the region and full programs differently, so agreement is
    # to f64 round-off, not bitwise (the Rust native path *is* bitwise).
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-13, atol=1e-14)


@settings(max_examples=15, deadline=None)
@given(
    nx=st.integers(7, 16),
    ny=st.integers(7, 16),
    nz=st.integers(7, 16),
    wx=st.integers(0, 3),
    wy=st.integers(0, 3),
    wz=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_diffusion_regions_compose_hypothesis(nx, ny, nz, wx, wy, wz, seed):
    if 2 * wx > nx - 2 or 2 * wy > ny - 2 or 2 * wz > nz - 2:
        return
    rng = np.random.default_rng(seed)
    T = jnp.asarray(rng.standard_normal((nx, ny, nz)))
    Ci = jnp.asarray(rng.uniform(0.1, 1.0, (nx, ny, nz)))
    got = compose_diffusion(T, Ci, (wx, wy, wz))
    want = ref.diffusion_step(T, Ci, **PARAMS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-13, atol=1e-14)


def test_twophase_regions_compose_to_full_step():
    rng = np.random.default_rng(1)
    shape = (14, 12, 16)
    Pe = jnp.asarray(rng.standard_normal(shape) * 0.1)
    phi = jnp.asarray(rng.uniform(0.01, 0.05, shape))
    inner, boundaries = model.split_regions(shape, (3, 2, 4))
    Pe2 = jnp.array(Pe)
    phi2 = jnp.array(phi)
    scalars = [TP_PARAMS[name] for name in model.TWOPHASE_SCALARS]
    for _, region in [("inner", inner)] + boundaries:
        UPe, Uphi = model.twophase_region(region)(Pe, phi, *scalars)
        Pe2 = model.scatter_region(Pe2, UPe, region)
        phi2 = model.scatter_region(phi2, Uphi, region)
    want_pe, want_phi = ref.twophase_step(Pe, phi, **TP_PARAMS)
    np.testing.assert_allclose(np.asarray(Pe2), np.asarray(want_pe), rtol=1e-13, atol=1e-14)
    np.testing.assert_allclose(np.asarray(phi2), np.asarray(want_phi), rtol=1e-13, atol=1e-14)
