"""L1 correctness: Pallas two-phase flow kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, twophase

jax.config.update("jax_enable_x64", True)

PARAMS = dict(
    dtau=1e-4, dt=1e-3, dx=0.1, dy=0.12, dz=0.09, eta=1.0, rhog=1.0, phiref=0.05, npow=3.0
)


def rand_fields(rng, shape):
    Pe = jnp.asarray(rng.standard_normal(shape) * 0.1)
    phi = jnp.asarray(rng.uniform(0.01, 0.05, shape))
    return Pe, phi


def test_step_matches_ref_fixed_shape():
    rng = np.random.default_rng(0)
    Pe, phi = rand_fields(rng, (11, 9, 13))
    got_pe, got_phi = twophase.step(Pe, phi, **PARAMS)
    want_pe, want_phi = ref.twophase_step(Pe, phi, **PARAMS)
    np.testing.assert_allclose(got_pe, want_pe, rtol=1e-12, atol=1e-15)
    np.testing.assert_allclose(got_phi, want_phi, rtol=1e-12, atol=1e-15)


def test_step_preserves_boundary_planes():
    rng = np.random.default_rng(1)
    Pe, phi = rand_fields(rng, (8, 10, 9))
    Pe2, phi2 = twophase.step(Pe, phi, **PARAMS)
    for arr, arr2 in ((Pe, Pe2), (phi, phi2)):
        for axis in range(3):
            for idx in (0, -1):
                np.testing.assert_array_equal(
                    np.take(np.asarray(arr2), idx, axis=axis),
                    np.take(np.asarray(arr), idx, axis=axis),
                )


def test_uniform_state_relaxes_pressure_only():
    # With uniform phi and Pe, fluxes vanish (no buoyancy divergence either:
    # rhog enters qz uniformly so div q = 0) and Pe relaxes toward 0 at rate
    # dtau / (eta * (1 - phi)).
    shape = (9, 9, 9)
    phi0 = 0.03
    pe0 = 0.2
    Pe = jnp.full(shape, pe0)
    phi = jnp.full(shape, phi0)
    Pe2, phi2 = twophase.step(Pe, phi, **PARAMS)
    expect_inner = pe0 * (1.0 - PARAMS["dtau"] / (PARAMS["eta"] * (1.0 - phi0)))
    np.testing.assert_allclose(Pe2[1:-1, 1:-1, 1:-1], expect_inner, rtol=1e-12)
    # phi update follows Pe2 with the (1 - phi) closure
    expect_phi = phi0 + PARAMS["dt"] * (1.0 - phi0) * expect_inner / PARAMS["eta"]
    np.testing.assert_allclose(phi2[1:-1, 1:-1, 1:-1], expect_phi, rtol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    nx=st.integers(3, 12),
    ny=st.integers(3, 12),
    nz=st.integers(3, 12),
    seed=st.integers(0, 2**31 - 1),
    dtau=st.floats(1e-6, 1e-3),
    rhog=st.floats(0.0, 2.0),
)
def test_step_matches_ref_hypothesis(nx, ny, nz, seed, dtau, rhog):
    rng = np.random.default_rng(seed)
    Pe, phi = rand_fields(rng, (nx, ny, nz))
    p = dict(PARAMS, dtau=dtau, rhog=rhog)
    got_pe, got_phi = twophase.step(Pe, phi, **p)
    want_pe, want_phi = ref.twophase_step(Pe, phi, **p)
    np.testing.assert_allclose(got_pe, want_pe, rtol=1e-12, atol=1e-15)
    np.testing.assert_allclose(got_phi, want_phi, rtol=1e-12, atol=1e-15)


def test_iterated_stability():
    # A Gaussian porosity blob iterated a few hundred pseudo-steps stays
    # finite and bounded — the configuration the Fig. 3 analog runs.
    shape = (16, 16, 16)
    n = shape[0]
    ax = jnp.arange(n, dtype=jnp.float64)
    x, y, z = jnp.meshgrid(ax, ax, ax, indexing="ij")
    c = (n - 1) / 2.0
    r2 = (x - c) ** 2 + (y - c) ** 2 + (z - 0.3 * n) ** 2
    phi = 0.01 + 0.04 * jnp.exp(-r2 / (0.1 * n**2))
    Pe = jnp.zeros(shape)
    p = dict(PARAMS, dtau=5e-4, dt=5e-4)
    for _ in range(200):
        Pe, phi = ref.twophase_step(Pe, phi, **p)
    assert bool(jnp.all(jnp.isfinite(Pe)))
    assert bool(jnp.all(jnp.isfinite(phi)))
    assert float(jnp.max(jnp.abs(Pe))) < 10.0
    assert 0.0 < float(jnp.min(phi)) and float(jnp.max(phi)) < 1.0
