#!/usr/bin/env python3
"""Derive the seed perf-trend baselines from the timing models.

The halo benches' wall times are dominated by *modeled* costs — the
network model's transit/injection sleeps (NetModel::aries: 1.5 us latency,
10 GB/s) and the staged path's PCIe copy charges (CopyModel::pcie3: 10 us,
11 GB/s) — so a first-order analytic estimate of every timing column is
reproducible from the model constants plus a small host-overhead floor.
This script encodes those formulas and emits the two baseline JSONs with
the exact schema the benches write, giving `tools/perf_trend.rs` something
honest to diff against before a quiet >2-core runner has recorded a
measured refresh (see README.md; timings are compared with a generous
relative tolerance and stay advisory in CI — only the allocation columns,
which are exact by contract, block).

Run from the repo root:  python3 bench/baselines/derive_baselines.py
"""

import json
import math
import os

# model constants (rust/src/mpisim/netmodel.rs, rust/src/memory/device.rs)
NET_LAT, NET_BW = 1.5e-6, 10e9  # aries
PCIE_LAT, PCIE_BW = 10e-6, 11e9  # pcie3
# host-side floor per update: thread wakeups, pool lock, precise_sleep slack
OH = 12e-6
MEMCPY_BW = 8e9  # contiguous pack/unpack, single thread
STRIDED_BW = 1.5e9  # dim-2 gather/scatter, single thread
THREAD_SPEEDUP = 3.0  # strided pack at 4 workers (memory-bound)


def sig3(x):
    return float(f"{x:.3g}")


def transit(bytes_):
    return NET_LAT + bytes_ / NET_BW


def copy(bytes_):
    return PCIE_LAT + bytes_ / PCIE_BW


def x_exchange_row(n):
    b = 8 * n * n
    pack = 2 * b / MEMCPY_BW  # x-plane: contiguous pack + unpack
    rdma = OH + pack + transit(b)

    def staged(c):
        # serial d2h chunks + last chunk's transit + serial h2d chunks
        return OH + pack + 2 * (c * PCIE_LAT + b / PCIE_BW) + transit(b / c)

    # serial-nic: rdma has one send per rank (no self-contention); staged
    # c=4 serializes its 4 chunk injections => + one full injection b/NET_BW
    return {
        "n": n,
        "rdma_s": sig3(rdma),
        "staged1_s": sig3(staged(1)),
        "staged4_s": sig3(staged(4)),
        "staged8_s": sig3(staged(8)),
        "rdma_serialnic_s": sig3(rdma),
        "staged4_serialnic_s": sig3(staged(4) + b / NET_BW),
        "pipelined": True,
        "steady_state_allocs": 0,
    }


def z_exchange_row(n):
    # z-split pair, field [n, n, 8], 2 fields: strided dim-2 planes of n^2
    b = 8 * n * n
    pack1 = 4 * b / STRIDED_BW  # 2 fields x (gather + scatter), serial
    pack4 = pack1 / THREAD_SPEEDUP
    rdma1 = OH + pack1 + transit(b)  # the 2 fields' transits overlap
    rdma4 = OH + pack4 + transit(b)
    stage_cost = 2 * 2 * (4 * PCIE_LAT + b / PCIE_BW)  # 2 fields, d2h + h2d
    st1 = OH + pack1 + stage_cost + transit(b / 4)
    st4 = OH + pack4 + stage_cost + transit(b / 4)
    return {
        "n": n,
        "pack_threads": 4,
        "pipelined": True,
        "rdma_s": sig3(rdma1),
        "rdma_threaded_s": sig3(rdma4),
        "staged4_s": sig3(st1),
        "staged4_threaded_s": sig3(st4),
        "steady_state_allocs": 0,
    }


def fault_idle_row(n):
    # enabled-but-idle fault layer: the epoch fold, receive deadlines and
    # the injector's decide() are atomic reads/arithmetic on the hot path,
    # so the first-order timing model is the clean x-exchange unchanged.
    # The gate columns are exact by contract: zero steady-state
    # allocations, zero injections, zero refusals.
    x = x_exchange_row(n)
    return {
        "n": n,
        "rdma_s": x["rdma_s"],
        "staged4_s": x["staged4_s"],
        "steady_state_allocs": 0,
        "fault_injected": 0,
        "fault_refused": 0,
    }


# pool dispatch is ~10x cheaper than the scoped spawns the 8192-cell gate
# was set against (EXPERIMENTS.md §Scheduler), so the crossover moved to 2048
PACK_GATE_CELLS = 2048


def pack_unpack_rows():
    rows = []
    for n in (32, 64, 128):
        for dim in (0, 1, 2):
            cells = n * n
            base = STRIDED_BW if dim == 2 else MEMCPY_BW
            for threads in (1, 4):
                gbs = base / 1e9
                # below the 2048-cell pool gate (every n=32 plane) packs
                # stay scalar; above it, threading pays most on the
                # strided dim. n=64 (4096 cells) clears the pool gate but
                # not the old spawn gate — the moved crossover, in rows.
                if threads == 4 and cells >= PACK_GATE_CELLS:
                    gbs *= THREAD_SPEEDUP if dim == 2 else 1.3
                rows.append({"n": n, "dim": dim, "threads": threads, "gbs": sig3(gbs)})
    return rows


def halo_baseline():
    return {
        "exchange": [x_exchange_row(n) for n in (32, 96, 256, 384)],
        "z_exchange": [z_exchange_row(n) for n in (96, 256, 384)],
        "fault_idle": [fault_idle_row(n) for n in (96, 256)],
        "pack_unpack": pack_unpack_rows(),
        "pack_gate_cells": PACK_GATE_CELLS,
        "pack_threads": 4,
        "pipelined": True,
        "steady_state_allocs": 0,
    }


def ablation_baseline():
    # CI shape: 4-core runner => 2 ranks, 32^3/rank, diffusion.
    # t_comp ~ 0.85 ms/step single thread; exchange one 32^2 x-plane.
    t_comp = 0.85e-3
    rows = []
    for name, scale, contended in (
        ("ideal", None, False),
        ("aries", 1.0, False),
        ("aries:8 (slow)", 8.0, False),
        ("aries:64 (very slow)", 64.0, False),
        ("aries:8,serial-nic", 8.0, True),
        ("aries:64,serial-nic", 64.0, True),
    ):
        b = 8 * 32 * 32
        if scale is None:
            t_x = 0.0
        else:
            t_x = NET_LAT * scale + b / (NET_BW / scale)
            if contended:
                t_x += b / (NET_BW / scale)  # serialized second injection share
        plain = t_comp + t_x + OH
        hidden = max(t_comp, t_x) + 0.05e-3 + OH  # boundary slabs overhead
        rows.append(
            {
                "net": name,
                "contended": contended,
                "plain_s": sig3(plain),
                "hidden_s": sig3(hidden),
            }
        )
    threads_rows = []
    t1 = 6.8e-3  # 64^3 diffusion step, single thread
    for threads, speedup in ((1, 1.0), (2, 1.9), (4, 3.4)):
        threads_rows.append(
            {
                "threads": threads,
                "t_step_s": sig3(t1 / speedup),
                "speedup": sig3(speedup),
            }
        )
    return {"hide": rows, "compute_threads": threads_rows}


# ---- weak scaling (fig2/fig3 sections of BENCH_perf.json) -------------
#
# The measured sweeps run on the bounded rank executor (carrier_sweep:
# 1..1331 on any host, 2197 where the budget allows), so the baseline only
# pins the machine-portable column: normalized parallel efficiency
# (bench::scaling::normalized_efficiency strips ideal core time-sharing).
# The formulas mirror bench::scaling::PerfModel: per-dim halo cost
# f_serial*(transit + pack), hiding overlaps it with the inner region, and
# a straggler term sigma*sqrt(2 ln P) keeps large-P efficiency below 1.

F_SERIAL = 2.0
SIGMA_FRAC = 0.02  # per-step jitter as a fraction of t1 (quiet-host figure)
SWEEP = [1, 8, 64, 216, 512, 1331, 2197]


def halo_time(nfields):
    # 32^3 local => 32*32 planes; x/y contiguous pack, z strided
    b = 8 * 32 * 32
    t = 0.0
    for pack_bw in (MEMCPY_BW, MEMCPY_BW, STRIDED_BW):
        t += F_SERIAL * (transit(b) + 2 * b / pack_bw)
    return nfields * t


def model_efficiency(P, t_comp, nfields, hide):
    if P <= 1:
        return 1.0
    # hide (4,2,2) on a 32^3 local: inner 22x26x26 of the 30^3 interior
    frac_inner = (22 * 26 * 26) / (30 * 30 * 30)
    t_inner, t_boundary = t_comp * frac_inner, t_comp * (1 - frac_inner)
    th = halo_time(nfields)
    t1 = t_comp
    tp = t_boundary + max(t_inner, th) if hide else t_comp + th
    straggler = SIGMA_FRAC * t1 * math.sqrt(2 * math.log(P))
    return t1 / (tp + straggler)


def eff_rows(points, t_comp, nfields, hide):
    return [
        {"nranks": p, "efficiency": sig3(model_efficiency(p, t_comp, nfields, hide))}
        for p in points
    ]


def weak_scaling_baseline():
    t_diff = 0.85e-3  # 32^3 diffusion step, single thread (see ablation)
    t_two = 2.5e-3  # 32^3 two-phase step (2 fields, heavier stencil)
    fig3_pts = [p for p in SWEEP if p <= 1331]  # fig3 sweep cap
    return {
        "fig2_weak_scaling": {
            "rows": eff_rows(SWEEP, t_diff, 1, hide=True),
            "modeled_efficiency_2197": sig3(model_efficiency(2197, t_diff, 1, True)),
        },
        "fig3_weak_scaling": {
            "rows_hidden": eff_rows(fig3_pts, t_two, 2, hide=True),
            "rows_plain": eff_rows(fig3_pts, t_two, 2, hide=False),
            "modeled_efficiency_1024": sig3(model_efficiency(1024, t_two, 2, True)),
        },
    }


def tenancy_baseline():
    """Co-tenancy QoS baseline (benches/tenancy_qos.rs).

    Only machine-portable columns: qos_efficiency already divides out core
    time-sharing, so on an ideally isolating fabric it is 1.0 for every job
    regardless of the runner's core count, and two equal-demand jobs are
    perfectly fair. Step times depend on the runner and stay out of the
    baseline (perf_trend only diffs shared paths). The fault counters are
    exact by contract: a clean co-tenancy run must not inject anything.
    """
    return {
        "jobs": [
            {"app": "diffusion", "nranks": 2, "qos_efficiency": 1.0},
            {"app": "wave", "nranks": 2, "qos_efficiency": 1.0},
        ],
        "fairness": 1.0,
        "total_ranks": 4,
        "fault_injected": 0,
        "fault_exhausted": 0,
    }


def ckpt_baseline():
    """Checkpoint-overhead baseline (benches/ckpt_overhead.rs).

    The cadence sweep's portable column is step_efficiency = t_step(off) /
    t_step(every): core time-sharing divides out, leaving the snapshot
    cost — first-order, four ~snap-sized copies per save (own slot fill,
    buddy payload build, mailbox deposit, buddy's held-slot drain) at the
    contiguous memcpy bandwidth, amortized over the cadence. t_step_s
    assumes the 2-core CI runner (4 ranks => 2x time-sharing) and stays
    advisory. The counters are exact by contract: saves follow the cadence
    arithmetic (nranks * nt/every) and a clean run never restores or
    injects.
    """
    nranks, nt, t_comp, oversub = 4, 16, 0.85e-3, 2.0
    b = 8 * 32 * 32
    t_x = 2 * (transit(b) + b / NET_BW)  # serial-nic: serialized 2nd injection
    snap = 2 * 8 * 34**3  # diffusion ckpt_fields: T + T2, halo-padded 32^3
    save = 4 * snap / MEMCPY_BW
    t0 = oversub * t_comp + t_x + OH
    rows = []
    for every in (0, 8, 4, 2, 1):
        t = t0 + (oversub * save / every if every else 0.0)
        rows.append(
            {
                "every": every,
                "t_step_s": sig3(t),
                "step_efficiency": sig3(t0 / t),
                "ckpt_saves": nranks * (nt // every) if every else 0,
                "ckpt_restores": 0,
                "fault_injected": 0,
            }
        )
    return {
        "app": "diffusion",
        "nranks": nranks,
        "n": 32,
        "nt": nt,
        "net": "aries,serial-nic",
        "rows": rows,
    }


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    for name, body in (
        ("BENCH_halo.json", halo_baseline()),
        ("hide_communication_ablation.json", ablation_baseline()),
        ("BENCH_weak_scaling.json", weak_scaling_baseline()),
        ("BENCH_tenancy.json", tenancy_baseline()),
        ("BENCH_ckpt.json", ckpt_baseline()),
    ):
        path = os.path.join(here, name)
        with open(path, "w") as f:
            json.dump(body, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
